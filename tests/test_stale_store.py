"""HaloExchange compact store: push/pull semantics, precision, and parity
with the dense reference store (repro.core.stale_store)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import halo_exchange as hx
from repro.core import stale_store
from repro.graph import build_partitions, make_dataset


def test_push_pull_roundtrip_compact():
    store = hx.init_store(2, 10, 4)
    slots = jnp.asarray([[0, 3, 10], [5, 7, 10]])       # 10 = sentinel pad
    valid = jnp.asarray([[True, True, False], [True, True, False]])
    reps = jnp.arange(2 * 2 * 3 * 4, dtype=jnp.float32).reshape(2, 2, 3, 4)
    store = hx.push(store, slots, valid, reps)
    pulled = hx.pull(store, slots)
    np.testing.assert_allclose(np.asarray(pulled)[:, :, :2],
                               np.asarray(reps)[:, :, :2])
    # sentinel row must stay zero (padding reads are zeros)
    assert float(jnp.abs(store["data"][:, 10]).max()) == 0.0


@pytest.mark.parametrize("storage", ["fp32", "bf16", "int8"])
def test_sentinel_stays_zero_all_precisions(storage):
    store = hx.init_store(1, 6, 8, hx.HaloPrecision(storage))
    slots = jnp.asarray([[0, 2, 6, 6]])
    valid = jnp.asarray([[True, True, True, False]])   # valid row → sentinel
    reps = jnp.full((1, 1, 4, 8), 3.7, jnp.float32)
    store = hx.push(store, slots, valid, reps)
    assert float(jnp.abs(store["data"][:, 6].astype(jnp.float32)).max()) == 0
    pulled = hx.pull(store, jnp.asarray([[6, 6]]))
    assert float(jnp.abs(pulled).max()) == 0.0


def test_pull_shape():
    store = hx.init_store(3, 20, 8)
    slots = jnp.asarray([[1, 2, 20], [4, 20, 20]])
    assert hx.pull(store, slots).shape == (2, 3, 3, 8)


def test_int8_quantization_error_bound():
    rng = np.random.default_rng(0)
    reps = rng.normal(size=(2, 2, 5, 16)).astype(np.float32) * 3.0
    store = hx.init_store(2, 10, 16, hx.HaloPrecision("int8"))
    slots = jnp.asarray([[0, 1, 2, 3, 4], [5, 6, 7, 8, 9]])
    valid = jnp.ones((2, 5), bool)
    store = hx.push(store, slots, valid, jnp.asarray(reps))
    pulled = np.asarray(hx.pull(store, slots))
    # symmetric per-row int8: |err| <= scale/2 = max|row| / 254, use /127
    bound = np.abs(reps).max(axis=-1, keepdims=True) / 127.0
    assert (np.abs(pulled - reps) <= bound + 1e-6).all()
    # and int8 really is the storage dtype
    assert store["data"].dtype == jnp.int8
    assert "scale" in store


def test_bf16_roundtrip_error():
    rng = np.random.default_rng(1)
    reps = rng.normal(size=(1, 1, 4, 8)).astype(np.float32)
    store = hx.init_store(1, 8, 8, hx.HaloPrecision("bf16"))
    slots = jnp.asarray([[0, 1, 2, 3]])
    store = hx.push(store, slots, jnp.ones((1, 4), bool), jnp.asarray(reps))
    pulled = np.asarray(hx.pull(store, slots))
    # bf16 has 8 significand bits → relative error ≤ 2^-8
    assert (np.abs(pulled - reps) <= np.abs(reps) * 2.0 ** -8 + 1e-7).all()


@pytest.fixture(scope="module")
def parts():
    g = make_dataset("flickr-sim", scale=0.1, seed=2)
    return g, build_partitions(g, 3)


def test_fp32_parity_with_dense_reference(parts):
    """Compact fp32 pull/push/staleness must agree with the dense seed
    store on every row it serves (boundary rows)."""
    g, sp = parts
    L1, hid = 2, 16
    rng = np.random.default_rng(3)
    reps = rng.normal(size=(sp.num_parts, L1, sp.part_size, hid)) \
        .astype(np.float32)
    lid = jnp.asarray(sp.local_ids)
    lval = jnp.asarray(sp.local_valid)

    dense = stale_store.init_store(L1, g.num_nodes, hid)
    dense = stale_store.push(dense, lid, lval, jnp.asarray(reps))
    compact = hx.init_store(L1, sp.num_boundary, hid)
    compact = hx.push(compact, jnp.asarray(sp.local_slots), lval,
                      jnp.asarray(reps))

    # Every halo pull identical (halo rows are boundary by construction).
    want = stale_store.pull(dense, jnp.asarray(sp.halo_ids))
    got = hx.pull(compact, jnp.asarray(sp.halo_slots))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    # staleness_error identical when the dense one is masked to the rows
    # the compact store serves.
    fresh = jnp.asarray(reps + rng.normal(size=reps.shape)
                        .astype(np.float32) * 0.1)
    served = lval & jnp.asarray(sp.local_slots < sp.num_boundary)
    eps_dense = stale_store.staleness_error(dense, fresh, lid, served)
    eps_compact = hx.staleness_error(compact, fresh,
                                     jnp.asarray(sp.local_slots), lval)
    np.testing.assert_allclose(np.asarray(eps_compact),
                               np.asarray(eps_dense), rtol=1e-6)


def test_boundary_map_consistency(parts):
    """store_map / store_ids / slot views agree with the id views."""
    g, sp = parts
    B = sp.num_boundary
    assert sp.store_ids.shape == (B + 1,)
    assert sp.store_ids[-1] == g.num_nodes
    # round-trip: slot → global → slot
    assert (sp.store_map[sp.store_ids[:B]] == np.arange(B)).all()
    # every valid halo entry maps to a real slot, padding to the sentinel
    assert (sp.halo_slots[sp.halo_valid] < B).all()
    assert (sp.halo_slots[~sp.halo_valid] == B).all()
    # out-ELL remaps are consistent with the halo-slot view
    ext_s = np.concatenate([sp.halo_slots, np.full((sp.num_parts, 1), B,
                                                   np.int32)], axis=1)
    ext_g = np.concatenate([sp.halo_ids, np.full((sp.num_parts, 1),
                                                 g.num_nodes, np.int32)],
                           axis=1)
    for m in range(sp.num_parts):
        np.testing.assert_array_equal(sp.out_nbr_store[m],
                                      ext_s[m][sp.out_nbr[m]])
        np.testing.assert_array_equal(sp.out_nbr_global[m],
                                      ext_g[m][sp.out_nbr[m]])


def test_comm_and_memory_accounting(parts):
    g, sp = parts
    spec32 = hx.HaloSpec.from_partitions(sp, 64, 3)
    spec8 = hx.HaloSpec.from_partitions(sp, 64, 3, hx.HaloPrecision("int8"))
    # compact store is O(|boundary|), not O(N)
    assert spec32.store_nbytes() == 2 * (sp.num_boundary + 1) * 64 * 4
    assert spec32.store_nbytes() <= spec32.dense_nbytes(g.num_nodes)
    # int8 wire bytes ≈ 4× less than fp32 (modulo the per-row scale)
    c32 = spec32.comm_bytes(sp.pull_rows(), sp.push_rows())
    c8 = spec8.comm_bytes(sp.pull_rows(), sp.push_rows())
    assert c8["total_bytes"] < c32["total_bytes"] / 3
    ratio = c32["pull_bytes"] / c8["pull_bytes"]
    assert 3.0 < ratio <= 4.0
