"""Pure-jnp oracle for the ELL SpMM kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def spmm_ref(nbr: jax.Array, wts: jax.Array, table: jax.Array) -> jax.Array:
    """out[i] = sum_k wts[i,k] * table[nbr[i,k]] — vectorized gather form."""
    gathered = jnp.take(table, nbr, axis=0)        # (rows, deg, feat)
    w = wts.astype(jnp.float32)[..., None]
    return jnp.sum(w * gathered.astype(jnp.float32), axis=1)


def halo_spmm_ref(nbr: jax.Array, wts: jax.Array, data: jax.Array,
                  scale: jax.Array = None, pdata: jax.Array = None,
                  pscale: jax.Array = None,
                  gamma: float = 1.0) -> jax.Array:
    """Fused pull+aggregate oracle: SpMM against a (possibly quantized)
    compact slab with per-row dequant scales folded into the weights.

    With a predictor slab (``pdata``/``pscale`` — the SAT history rows,
    same layout as ``data``/``scale``) each gathered row is the
    staleness-alleviated prediction
    ``dequant(data[s]) + gamma * dequant(pdata[s])``."""
    w = wts.astype(jnp.float32)
    ws = w
    if scale is not None:
        ws = w * jnp.take(scale[:, 0], nbr, axis=0)
    gathered = jnp.take(data, nbr, axis=0).astype(jnp.float32)
    out = jnp.sum(ws[..., None] * gathered, axis=1)
    if pdata is not None:
        wp = w * jnp.float32(gamma)
        if pscale is not None:
            wp = wp * jnp.take(pscale[:, 0], nbr, axis=0)
        pgathered = jnp.take(pdata, nbr, axis=0).astype(jnp.float32)
        out = out + jnp.sum(wp[..., None] * pgathered, axis=1)
    return out


def halo_spmm_skip_ref(nbr: jax.Array, wts: jax.Array, data: jax.Array,
                       scale: jax.Array, wl_ids, wl_cnt,
                       chunk_rows: int, block_rows: int = 128,
                       pdata: jax.Array = None, pscale: jax.Array = None,
                       gamma: float = 1.0) -> jax.Array:
    """Worklist-masked oracle for the chunk-skipping streamed kernel.

    Accumulates only the contributions whose slab row falls inside a
    *visited* chunk of the (row_block × chunk) worklist — so it equals
    :func:`halo_spmm_ref` iff the worklist covers every referenced slot
    (the completeness property the skip kernel's correctness rests on),
    and it diverges loudly on a deliberately truncated worklist."""
    import numpy as np

    rows = nbr.shape[0]
    ids = np.asarray(wl_ids)
    cnt = np.asarray(wl_cnt)
    n_blocks = ids.shape[0]
    n_chunks = max(-(-data.shape[0] // chunk_rows), 1)
    # visited[i, c]: chunk c is on row block i's worklist.
    visited = np.zeros((n_blocks, n_chunks), bool)
    for i in range(n_blocks):
        visited[i, ids[i, :cnt[i]]] = True
    visited = jnp.asarray(visited)
    block_of = jnp.minimum(jnp.arange(rows) // block_rows, n_blocks - 1)
    in_visited = visited[block_of[:, None], nbr // chunk_rows]
    w = wts.astype(jnp.float32) * in_visited.astype(jnp.float32)
    ws = w
    if scale is not None:
        ws = w * jnp.take(scale[:, 0], nbr, axis=0)
    gathered = jnp.take(data, nbr, axis=0).astype(jnp.float32)
    out = jnp.sum(ws[..., None] * gathered, axis=1)
    if pdata is not None:
        wp = w * jnp.float32(gamma)
        if pscale is not None:
            wp = wp * jnp.take(pscale[:, 0], nbr, axis=0)
        pgathered = jnp.take(pdata, nbr, axis=0).astype(jnp.float32)
        out = out + jnp.sum(wp[..., None] * pgathered, axis=1)
    return out
