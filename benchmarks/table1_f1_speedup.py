"""Table 1: F1 + speedup for {LLCG, DGL, DIGEST, DIGEST-A} × GCN/GAT ×
four dataset stand-ins.

Speedup is reported two ways (both normalized to DGL=propagation):
  * measured CPU per-epoch wall time (relative behaviour), and
  * the analytic §3.3 communication-model epoch time with v5e constants
    (`model_speedup`) — the deployable-cluster prediction.
"""
from __future__ import annotations

from benchmarks.common import bench_scale, emit
from benchmarks.gnn_common import DATASETS, MODE_LABEL, setup, train_mode
from repro.core import epoch_time_model
from repro.models.gnn import gnn_specs
from repro.nn import param_count


def run(models=("gcn", "gat"), datasets=None, epochs=None) -> list[dict]:
    scale = bench_scale()
    datasets = datasets or DATASETS
    epochs = epochs or max(int(120 * scale), 30)
    rows = []
    for model in models:
        for ds in datasets:
            g, data, cfg = setup(ds, model=model,
                                 scale=0.3 * scale if ds == "products-sim"
                                 else 0.35 * scale)
            pc = param_count(gnn_specs(cfg))
            base_time = None
            for mode in ("propagation", "llcg", "digest", "digest_a"):
                hist, wall, per_epoch = train_mode(cfg, data, mode, epochs)
                t_model = epoch_time_model(
                    {"digest_a": "digest", "llcg": "partition"}.get(
                        mode, mode),
                    data["_sp"], g, pc, cfg.hidden_dim, cfg.num_layers,
                    cfg.in_dim)["t_epoch"]
                if mode == "propagation":
                    base_time, base_model = per_epoch, t_model
                rows.append({
                    "name": f"table1/{model}/{ds}/{MODE_LABEL[mode]}",
                    "us_per_call": round(per_epoch * 1e6, 1),
                    "f1": round(hist["val_f1"][-1], 4),
                    "speedup_measured": round(base_time / per_epoch, 3),
                    "speedup_model": round(base_model / t_model, 3),
                })
    return rows


if __name__ == "__main__":
    emit(run())
