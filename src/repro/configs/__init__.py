"""Config registry: the paper's own GNN configs + 10 assigned architectures.

``get_arch(name)`` returns the full production ArchConfig;
``get_smoke_arch(name)`` returns the reduced same-family variant used by the
CPU smoke tests (≤2 layers, d_model ≤ 512, ≤4 experts).
"""
from __future__ import annotations

import importlib

from repro.models.transformer import ArchConfig

ARCH_IDS = [
    "llama_3_2_vision_11b",
    "llama4_scout_17b_a16e",
    "deepseek_coder_33b",
    "kimi_k2_1t_a32b",
    "qwen3_0_6b",
    "recurrentgemma_9b",
    "xlstm_1_3b",
    "minitron_8b",
    "musicgen_large",
    "phi3_mini_3_8b",
]

# CLI-friendly aliases (the assignment's dashed ids).
ALIASES = {
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "qwen3-0.6b": "qwen3_0_6b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "xlstm-1.3b": "xlstm_1_3b",
    "minitron-8b": "minitron_8b",
    "musicgen-large": "musicgen_large",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
}


def _module(name: str):
    name = ALIASES.get(name, name)
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ALIASES)}")
    return importlib.import_module(f"repro.configs.{name}")


def get_arch(name: str) -> ArchConfig:
    return _module(name).CONFIG


def get_smoke_arch(name: str) -> ArchConfig:
    return _module(name).SMOKE


def all_archs() -> dict[str, ArchConfig]:
    return {n: get_arch(n) for n in ARCH_IDS}
