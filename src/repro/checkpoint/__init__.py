from repro.checkpoint.checkpoint import (CheckpointCorruptError,
                                         latest_step, read_manifest,
                                         restore_checkpoint, save_checkpoint,
                                         verify_checkpoint)

__all__ = ["CheckpointCorruptError", "latest_step", "read_manifest",
           "restore_checkpoint", "save_checkpoint", "verify_checkpoint"]
